"""Fleet tier, worker side: one process = one `SceneStore`-backed
`RenderEngine`, driven over a pipe by `serving.router.FleetRouter`.

The ROADMAP's "millions of users" story needs many hosts, and RT-NeRF's
hybrid encodings only pay off at scale when hot scenes stay *resident*
near the requests that need them: a single host serving an interleaved
multi-user stream across more scenes than its device memory holds spends
its time spilling and reviving encoded checkpoints instead of rendering.
The fleet tier restores that locality by sharding scenes across worker
processes with scene-affinity routing (`router.HashRing`) so each
worker's working set fits its budget.

This module owns everything that crosses the process boundary:

  * **Wire format** (`pack_msg`/`unpack_msg`): length-prefixed framing —
    a 4-byte big-endian JSON-header length, the UTF-8 JSON header, then
    for each array an 8-byte length prefix and its raw C-order bytes
    (dtype/shape carried in the header's ``_arrays`` table). No pickle:
    the protocol is explicit and versioned (``_v``), so a router and a
    worker from different builds fail loudly instead of silently
    mis-decoding. Messages travel over `multiprocessing.Pipe`
    connections via ``send_bytes``/``recv_bytes``.
  * **Scene export** (`export_scene`/`load_scene`): a scene's source of
    truth on shared storage — the encoded field (`ckpt.spill_field`,
    bitmap/COO streams as-is) plus its cube set
    (`store.save_cubes`). The router registers scenes on workers by
    path; a worker loads and registers bit-identically, which is what
    makes replicated hot scenes serve bit-identical frames from every
    replica and makes post-crash re-registration safe.
  * **Worker loop** (`worker_main`): drains all queued messages each
    cycle (so a burst micro-batches through one engine flush), answers
    control ops inline (register / evict / prefetch / pin / stats /
    inject / ping / shutdown), and resolves render ops through
    `RenderEngine.submit(...deadline_s=...)` — the engine's existing
    deadline semantics fail stale requests with a timed-out result
    instead of rendering them late, fleet or no fleet.

Fault injection is part of the protocol, not test monkey-patching: the
``inject`` op plants an artificial pre-flush stall in the worker, which
is how the test suite builds slow/stalled workers that still speak the
protocol (`tests/conftest.py::fleet_faults`). Worker death needs no
cooperation at all — a SIGKILLed worker's pipe EOFs and the router
re-hashes (`router.FleetRouter._on_worker_death`).

Prefetch-revival (`prefetch` op) runs `SceneStore.ensure_resident` on a
background thread so a predicted-next scene's disk I/O never blocks the
serving loop.
"""
from __future__ import annotations

import json
import os
import struct
import threading
import time
from typing import Dict, Optional, Tuple

import numpy as np

WIRE_VERSION = 1

# wire-safety allowlist (docs/static_analysis.md): the only dtypes a frame
# header may name. `unpack_msg` rejects anything else before frombuffer, so
# a malformed or hostile header can never make numpy reinterpret raw bytes
# as object/void/structured records.
WIRE_DTYPES = ("bool", "uint8", "uint16", "uint32", "uint64",
               "int8", "int16", "int32", "int64",
               "float16", "float32", "float64")

# repro-lint lock-discipline declarations (docs/static_analysis.md)
GUARDED_BY = {
    "_Worker": {"lock": "_prefetch_lock", "attrs": ("_prefetches",)},
}

# header-length prefix (u32) / per-array length prefix (u64)
_HDR_LEN = struct.Struct(">I")
_ARR_LEN = struct.Struct(">Q")


class WireError(ValueError):
    """A frame that does not decode under this protocol version."""


def pack_msg(msg: Dict) -> bytes:
    """Encode one message: JSON-able fields go in the header, top-level
    numpy arrays are hoisted into length-prefixed raw buffers described by
    the header's ``_arrays`` table. `unpack_msg` is the exact inverse."""
    head, arrays = {}, []
    for k, v in msg.items():
        if isinstance(v, np.ndarray):
            arrays.append((k, np.ascontiguousarray(v)))
        else:
            head[k] = v
    head["_v"] = WIRE_VERSION
    head["_arrays"] = [{"key": k, "dtype": str(a.dtype),
                        "shape": list(a.shape)} for k, a in arrays]
    hb = json.dumps(head).encode("utf-8")
    parts = [_HDR_LEN.pack(len(hb)), hb]
    for _, a in arrays:
        b = a.tobytes()
        parts.append(_ARR_LEN.pack(len(b)))
        parts.append(b)
    return b"".join(parts)


def unpack_msg(buf: bytes) -> Dict:
    """Decode one `pack_msg` frame back into a dict (arrays as numpy)."""
    if len(buf) < _HDR_LEN.size:
        raise WireError(f"frame too short ({len(buf)} bytes)")
    (hlen,) = _HDR_LEN.unpack_from(buf, 0)
    off = _HDR_LEN.size
    if len(buf) < off + hlen:
        raise WireError("truncated header")
    head = json.loads(buf[off:off + hlen].decode("utf-8"))
    off += hlen
    if head.get("_v") != WIRE_VERSION:
        raise WireError(f"wire version {head.get('_v')!r}, "
                        f"expected {WIRE_VERSION}")
    msg = {k: v for k, v in head.items() if k not in ("_v", "_arrays")}
    for spec in head["_arrays"]:
        if spec.get("dtype") not in WIRE_DTYPES:
            raise WireError(
                f"array '{spec.get('key')}' has dtype "
                f"{spec.get('dtype')!r}, not in the WIRE_DTYPES allowlist")
        (alen,) = _ARR_LEN.unpack_from(buf, off)
        off += _ARR_LEN.size
        raw = buf[off:off + alen]
        if len(raw) != alen:
            raise WireError(f"truncated array '{spec['key']}'")
        off += alen
        msg[spec["key"]] = np.frombuffer(
            raw, dtype=np.dtype(spec["dtype"])).reshape(spec["shape"]).copy()
    return msg


def cam_to_wire(cam) -> Dict:
    """Flatten a `rendering.Camera` into wire fields (prefix `cam_`)."""
    return {"cam_c2w": np.asarray(cam.c2w, np.float32),
            "cam_origin": np.asarray(cam.origin, np.float32),
            "cam_focal": float(cam.focal),
            "cam_h": int(cam.h), "cam_w": int(cam.w)}


def cam_from_wire(msg: Dict):
    import jax.numpy as jnp

    from repro.core.rendering import Camera

    return Camera(jnp.asarray(msg["cam_c2w"]), jnp.asarray(msg["cam_origin"]),
                  float(msg["cam_focal"]), int(msg["cam_h"]),
                  int(msg["cam_w"]))


# -- scene export (shared-storage source of truth) -------------------------


def export_scene(path: str, field, cubes=None, *, cfg=None,
                 scene: str = "") -> str:
    """Write a scene's registration source: the encoded field streams
    (`ckpt.spill_field`, bit-for-bit) + its cube set. Workers register
    from this path (`load_scene`), so every replica — and every post-crash
    re-registration — serves the identical representation. Cubes are
    rebuilt here once when not supplied (needs `cfg`)."""
    from repro.ckpt import checkpoint as ckpt_lib
    from repro.core import field as field_lib
    from repro.core import occupancy as occ_lib
    from repro.serving import store as store_lib

    if cfg is not None:
        field = field_lib.as_backend(field, cfg).encode()
    if cubes is None:
        if cfg is None:
            raise ValueError("export_scene needs cubes or cfg to build them")
        occ = occ_lib.build_occupancy(field, cfg)
        cubes = occ_lib.extract_cubes(occ, cfg)
    os.makedirs(path, exist_ok=True)
    ckpt_lib.spill_field(path, field, extra_meta={"scene": scene})
    store_lib.save_cubes(path, cubes)
    return path


def load_scene(path: str, cfg) -> Tuple[object, object]:
    """-> (FieldBackend, CubeSet): the exact representation `export_scene`
    wrote (same formats, packed bytes, cube geometry)."""
    from repro.ckpt import checkpoint as ckpt_lib
    from repro.serving import store as store_lib

    field, _ = ckpt_lib.unspill_field(path, cfg)
    return field, store_lib.load_cubes(path)


# -- worker process --------------------------------------------------------


class _Worker:
    """One worker's serving state: engine + store + injected faults."""

    def __init__(self, name: str, cfg, engine_kwargs: Dict):
        from repro.serving.engine import RenderEngine

        self.name = name
        self.cfg = cfg
        self.engine = RenderEngine(cfg, **engine_kwargs)
        self.stall_s = 0.0            # fault injection: pre-flush sleep
        self._prefetches = 0
        self._prefetch_lock = threading.Lock()
        self._prefetch_threads = []

    def register(self, scene: str, path: str, *, pin: bool = False,
                 priority: int = 0):
        field, cubes = load_scene(path, self.cfg)
        self.engine.register_scene(scene, field, cubes)
        store = self.engine.store
        if pin:
            store.pin(scene, True)
        if priority:
            store.set_priority(scene, priority)

    def prefetch(self, scene: str):
        """Async revival of a predicted-next scene: the disk I/O runs on a
        background thread so the serving loop never waits behind it."""
        def work():
            try:
                self.engine.store.ensure_resident(scene)
            except Exception:
                pass                  # scene may have been dropped meanwhile
            with self._prefetch_lock:
                self._prefetches += 1
        t = threading.Thread(target=work, name=f"{self.name}-prefetch",
                             daemon=True)
        t.start()
        self._prefetch_threads = [x for x in self._prefetch_threads
                                  if x.is_alive()] + [t]

    def stats(self) -> Dict:
        s = self.engine.stats()
        with self._prefetch_lock:
            prefetches = self._prefetches
        return {
            "worker": self.name,
            "views_served": s["views_served"],
            "fps": s["fps"],
            "latency_p50_s": s["latency_p50_s"],
            "latency_p95_s": s["latency_p95_s"],
            "timeouts": s["timeouts"],
            "queue_depth": self.engine.queue_depth(),
            "n_scenes": s["n_scenes"],
            "resident_scenes": s["resident_scenes"],
            "resident_bytes": s["resident_bytes"],
            "evictions": s["evictions"],
            "revivals": s["revivals"],
            "prefetches": prefetches,
            "scene_views": {n: sc["views_served"]
                            for n, sc in s["scenes"].items()},
        }


def worker_main(conn, name: str, cfg_fields: Dict, engine_kwargs: Dict):
    """Entry point of one fleet worker process (spawn-safe: module level,
    everything it needs arrives as plain dicts). Speaks the `pack_msg`
    protocol on `conn` until EOF or a ``shutdown`` op.

    Per cycle it drains every queued message in arrival order — control
    ops execute inline (pipe FIFO means a ``register`` sent ahead of the
    first ``render`` for a scene lands first), render ops queue into the
    engine and flush once as a micro-batch. A per-message failure answers
    that message with an ``err`` reply instead of killing the worker."""
    from repro.configs.rtnerf import NeRFConfig

    cfg = NeRFConfig(**cfg_fields)
    w = _Worker(name, cfg, engine_kwargs)

    def send(msg: Dict):
        conn.send_bytes(pack_msg(msg))

    running = True
    while running:
        try:
            frames = [conn.recv_bytes()]
        except (EOFError, OSError):
            break
        while conn.poll(0):
            try:
                frames.append(conn.recv_bytes())
            except (EOFError, OSError):
                running = False
                break
        renders = []
        for raw in frames:
            try:
                m = unpack_msg(raw)
                op = m.get("op")
                if op == "render":
                    cam = cam_from_wire(m)
                    gt = m.get("gt")
                    fut = w.engine.submit(cam, gt, scene=m["scene"],
                                          deadline_s=m.get("deadline_s"))
                    renders.append((m["req"], m["scene"], fut,
                                    time.perf_counter()))
                elif op == "register":
                    w.register(m["scene"], m["path"],
                               pin=bool(m.get("pin", False)),
                               priority=int(m.get("priority", 0)))
                    send({"op": "ok", "req": m.get("req")})
                elif op == "evict":
                    w.engine.store.evict(m["scene"])
                    send({"op": "ok", "req": m.get("req")})
                elif op == "prefetch":
                    w.prefetch(m["scene"])
                    send({"op": "ok", "req": m.get("req")})
                elif op == "pin":
                    store = w.engine.store
                    store.pin(m["scene"], bool(m.get("pinned", True)))
                    if "priority" in m:
                        store.set_priority(m["scene"], int(m["priority"]))
                    send({"op": "ok", "req": m.get("req")})
                elif op == "inject":
                    w.stall_s = float(m.get("stall_s", 0.0))
                    send({"op": "ok", "req": m.get("req")})
                elif op == "stats":
                    send({"op": "stats", "req": m.get("req"),
                          "stats": w.stats()})
                elif op == "ping":
                    send({"op": "pong", "req": m.get("req")})
                elif op == "shutdown":
                    running = False
                else:
                    raise WireError(f"unknown op {op!r}")
            except (EOFError, OSError, BrokenPipeError):
                running = False
                break
            except Exception as e:       # answer THIS message, keep serving
                try:
                    req = unpack_msg(raw).get("req")
                except Exception:
                    req = None
                try:
                    send({"op": "err", "req": req,
                          "error": f"{type(e).__name__}: {e}"})
                except (OSError, BrokenPipeError):
                    running = False
                    break
        if w.stall_s > 0:                # injected fault: stalled worker
            time.sleep(w.stall_s)
        if renders:
            try:
                w.engine.flush()
            except Exception as e:
                for req, scene, fut, _ in renders:
                    try:
                        send({"op": "err", "req": req,
                              "error": f"{type(e).__name__}: {e}"})
                    except (OSError, BrokenPipeError):
                        running = False
                renders = []
            for req, scene, fut, t0 in renders:
                try:
                    r = fut.result(timeout=60.0)
                except Exception as e:
                    send({"op": "err", "req": req,
                          "error": f"{type(e).__name__}: {e}"})
                    continue
                out = {"op": "result", "req": req, "scene": r.scene,
                       "worker": name, "timed_out": bool(r.timed_out),
                       "psnr": (None if r.psnr is None else float(r.psnr)),
                       "worker_latency_s": time.perf_counter() - t0}
                if r.img is not None:
                    out["img"] = np.asarray(r.img, np.float32)
                try:
                    send(out)
                except (OSError, BrokenPipeError):
                    running = False
                    break
    try:
        w.engine.close()
    except Exception:
        pass
    try:
        conn.close()
    except Exception:
        pass


def cfg_to_fields(cfg) -> Dict:
    """NeRFConfig -> plain dict for the spawn boundary."""
    import dataclasses

    return dataclasses.asdict(cfg)


def spawn_worker(ctx, name: str, cfg, engine_kwargs: Dict,
                 *, daemon: bool = False):
    """-> (Process, parent Connection). The child runs `worker_main`."""
    import multiprocessing as mp  # noqa: F401  (ctx carries the API)

    parent, child = ctx.Pipe(duplex=True)
    proc = ctx.Process(target=worker_main,
                       args=(child, name, cfg_to_fields(cfg),
                             dict(engine_kwargs)),
                       name=f"fleet-{name}", daemon=daemon)
    proc.start()
    child.close()
    return proc, parent


__all__ = ["WIRE_VERSION", "WireError", "pack_msg", "unpack_msg",
           "cam_to_wire", "cam_from_wire", "export_scene", "load_scene",
           "worker_main", "spawn_worker", "cfg_to_fields"]
