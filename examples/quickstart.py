"""Quickstart: the RT-NeRF pipeline end to end in ~2 minutes on CPU.

Trains a tiny TensoRF field on a procedural scene (compressed-native: the
factors stay hybrid-encoded between optimizer steps after the first
occupancy rebuild), builds the occupancy cube set, renders a novel view
through BOTH pipelines (uniform baseline vs the paper's efficient
pipeline), then sparsifies the field further and renders it straight from
the hybrid bitmap/COO encoding (Sec. 4.2.2) — the compressed-domain path
the RT-NeRF accelerator actually executes — and finally hot-swaps the
re-pruned field into a running serving engine (`swap_field`).

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --tiny   # CI smoke shape
"""
import argparse
import time

from repro.configs.rtnerf import NeRFConfig
from repro.core import occupancy as occ_lib
from repro.core import train as nerf_train
from repro.data import rays as rays_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=250)
    ap.add_argument("--res", type=int, default=56)
    ap.add_argument("--prune", type=float, default=0.9,
                    help="target factor sparsity for the compressed demo")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke shape: small field, 30 steps, 32^2")
    args = ap.parse_args()

    if args.tiny:
        args.steps, args.res = min(args.steps, 30), min(args.res, 32)
        cfg = NeRFConfig(grid_res=24, occ_res=24, cube_size=4, max_cubes=320,
                         r_sigma=4, r_color=8, app_dim=8, mlp_hidden=16,
                         max_samples_per_ray=64, train_rays=512)
    else:
        cfg = NeRFConfig(grid_res=40, occ_res=40, cube_size=4, max_cubes=768,
                         r_sigma=8, r_color=16, app_dim=12, mlp_hidden=32,
                         max_samples_per_ray=112, train_rays=1024)

    print("== training TensoRF field on procedural 'lego' ==")
    t0 = time.time()
    res = nerf_train.train_nerf(cfg, "lego", steps=args.steps, n_views=8,
                                image_hw=args.res,
                                log_every=max(args.steps // 2, 1))
    print(f"   {time.time() - t0:.0f}s; non-zero cubes: {res.cubes.count}")

    scene = rays_lib.make_scene("lego")
    cam = rays_lib.make_cameras(7, args.res, args.res)[2]
    gt = rays_lib.render_gt(scene, cam)

    print("== rendering a novel view ==")
    for pipeline, kw in (("uniform", {}), ("rtnerf", {"chunk": 8})):
        t0 = time.time()
        psnr, stats, img = nerf_train.eval_view(res.field, cfg, res.cubes,
                                                cam, gt, pipeline=pipeline,
                                                **kw)
        print(f"  {pipeline:8s} psnr={psnr:5.2f}  "
              f"occ_accesses={stats['occ_accesses']:9.0f}  "
              f"processed={stats['processed_samples']:9.0f}  "
              f"({time.time() - t0:.1f}s)")
    print("RT-NeRF pipeline: same quality, orders-of-magnitude fewer "
          "occupancy-structure accesses (paper Sec. 3.1/3.2).")

    print(f"== compressed-field rendering (prune to {args.prune:.0%}, "
          f"hybrid bitmap/COO) ==")
    cf = res.field.prune(sparsity=args.prune)    # re-encoded internally
    occ = occ_lib.build_occupancy(cf, cfg)       # cfg.occ_sigma_thresh
    cubes = occ_lib.extract_cubes(occ, cfg)
    for name, field in (("dense", cf.decode()), ("hybrid", cf)):
        t0 = time.time()
        psnr, stats, img = nerf_train.eval_view(field, cfg, cubes, cam, gt,
                                                pipeline="rtnerf", chunk=8)
        print(f"  {name:8s} psnr={psnr:5.2f}  "
              f"factor_bytes={stats['factor_bytes']:9.0f}  "
              f"({time.time() - t0:.1f}s)")
    print(f"hybrid codec: {cf.compression_ratio():.1f}x fewer factor bytes "
          "in the hot loop at matched quality (paper Sec. 4.2.2).")

    print("== streaming multi-view serving (RenderEngine) ==")
    # one resident compressed field, one jitted micro-batched render step,
    # octant-cached cube orderings: submit cameras, await futures
    from repro.serving import RenderEngine

    engine = RenderEngine(cfg, cf, cubes,
                          ray_chunk=args.res * args.res, max_batch_views=4)
    cams = rays_lib.make_cameras(4, args.res, args.res)
    futures = [engine.submit(c, rays_lib.render_gt(scene, c)) for c in cams]
    for f in futures:
        r = f.result()
        print(f"  view {r.view_id}: psnr={r.psnr:5.2f}  "
              f"latency={r.latency_s:.2f}s")
    s = engine.stats()
    print(f"engine: {s['fps']:.2f} FPS  p50={s['latency_p50_s']:.2f}s  "
          f"p95={s['latency_p95_s']:.2f}s  ordering-cache "
          f"hits={s['ordering_cache']['hits']}/"
          f"{s['ordering_cache']['hits'] + s['ordering_cache']['misses']}")
    print("batched serving amortises encode + compile + ordering across "
          "the request stream (benchmarks/serving_throughput.py).")

    print("== live field hot-swap (train->serve loop) ==")
    # publish a lighter (more aggressively pruned) field to the RUNNING
    # engine; queued requests are never dropped, the occupancy cube set is
    # rebuilt from the new field, and the jitted step is reused
    lighter = res.field.prune(sparsity=min(args.prune + 0.05, 0.97))
    engine.swap_field(lighter)
    r = engine.submit(cams[0], rays_lib.render_gt(scene, cams[0])).result()
    s = engine.stats()
    print(f"  swapped field: {s['compression_ratio']:.1f}x compression, "
          f"psnr={r.psnr:5.2f}, swaps={s['field_swaps']}")


if __name__ == "__main__":
    main()
