"""Quickstart: the RT-NeRF pipeline end to end in ~2 minutes on CPU.

Trains a tiny TensoRF field on a procedural scene, builds the occupancy
cube set, renders a novel view through BOTH pipelines (uniform baseline vs
the paper's efficient pipeline), and prints the paper's headline mechanism
numbers (occupancy-access reduction, processed points, PSNR parity).

    PYTHONPATH=src python examples/quickstart.py
"""
import time

from repro.configs.rtnerf import NeRFConfig
from repro.core import train as nerf_train
from repro.data import rays as rays_lib

cfg = NeRFConfig(grid_res=40, occ_res=40, cube_size=4, max_cubes=768,
                 r_sigma=8, r_color=16, app_dim=12, mlp_hidden=32,
                 max_samples_per_ray=112, train_rays=1024)

print("== training TensoRF field on procedural 'lego' ==")
t0 = time.time()
res = nerf_train.train_nerf(cfg, "lego", steps=250, n_views=8, image_hw=56,
                            log_every=125)
print(f"   {time.time() - t0:.0f}s; non-zero cubes: {res.cubes.count}")

scene = rays_lib.make_scene("lego")
cam = rays_lib.make_cameras(7, 56, 56)[2]
gt = rays_lib.render_gt(scene, cam)

print("== rendering a novel view ==")
for pipeline, kw in (("uniform", {}), ("rtnerf", {"chunk": 8})):
    t0 = time.time()
    psnr, stats, img = nerf_train.eval_view(res.params, cfg, res.cubes, cam,
                                            gt, pipeline=pipeline, **kw)
    print(f"  {pipeline:8s} psnr={psnr:5.2f}  "
          f"occ_accesses={stats['occ_accesses']:9.0f}  "
          f"processed={stats['processed_samples']:9.0f}  "
          f"({time.time() - t0:.1f}s)")
print("RT-NeRF pipeline: same quality, orders-of-magnitude fewer "
      "occupancy-structure accesses (paper Sec. 3.1/3.2).")
