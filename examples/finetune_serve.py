"""Online fine-tuning while serving: the train->serve loop, live.

A RenderEngine goes resident with a deliberately under-trained field, its
background flush thread serves a concurrent stream of view requests, and a
serving.FineTuneLoop fine-tunes the scene on a trainer thread — publishing
the refreshed hybrid-encoded field into the RUNNING engine via
`swap_field` every `--publish-every` steps. Watch served-view PSNR climb
across swaps while the request stream never stalls: zero dropped or
timed-out futures, and no retracing (the jitted step takes the field as a
pytree argument).

    PYTHONPATH=src python examples/finetune_serve.py
    PYTHONPATH=src python examples/finetune_serve.py --tiny   # CI smoke

Expected output shape (full run; numbers vary slightly):

    == serving from an under-trained field while fine-tuning ==
    view  12: psnr=13.87 swaps_seen=0 ...
    ...
    view 119: psnr=26.41 swaps_seen=5 ...
    == fine-tune/serve summary ==
    served 120 views, 0 timeouts, 6 live swaps (max swap 4.1ms)
    psnr before first swap 13.9 dB -> after last swap 26.2 dB
"""
import argparse
import threading
import time

import numpy as np

from repro.configs.rtnerf import demo_config
from repro.core import train as nerf_train
from repro.data import rays as rays_lib
from repro.serving import FineTuneLoop, RenderEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scene", default="lego")
    ap.add_argument("--res", type=int, default=48)
    ap.add_argument("--warmup-steps", type=int, default=5,
                    help="steps for the (bad) starting field")
    ap.add_argument("--finetune-steps", type=int, default=240)
    ap.add_argument("--publish-every", type=int, default=40)
    ap.add_argument("--flush-interval", type=float, default=0.25,
                    help="engine background flush interval (s)")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke shape: small field, 60 steps, 24^2")
    args = ap.parse_args()

    if args.tiny:
        args.res = min(args.res, 24)
        args.finetune_steps, args.publish_every = 60, 15
    cfg = demo_config(tiny=args.tiny)

    # an under-trained starting field: the fine-tuner has room to climb
    res = nerf_train.train_nerf(cfg, args.scene, steps=args.warmup_steps,
                                n_views=8, image_hw=args.res, verbose=False)
    engine = RenderEngine(cfg, res.field, res.cubes,
                          ray_chunk=args.res * args.res, max_batch_views=4,
                          auto_flush_interval=args.flush_interval)

    scene = rays_lib.make_scene(args.scene)
    cams = rays_lib.make_cameras(6, args.res, args.res)
    gts = [rays_lib.render_gt(scene, c) for c in cams]

    print("== serving from an under-trained field while fine-tuning ==")
    loop = FineTuneLoop(engine, args.scene, steps=args.finetune_steps,
                        publish_every=args.publish_every, n_views=8,
                        image_hw=args.res).start()

    records = []                                  # (psnr, swaps_seen)
    stream_errs = []

    def stream():
        try:
            i = 0
            while loop.running():
                fut = engine.submit(cams[i % len(cams)], gts[i % len(cams)])
                r = fut.result(timeout=600)
                swaps = engine.stats()["field_swaps"]
                records.append((r.psnr, swaps, r.timed_out))
                if i % 4 == 0:
                    print(f"view {i:4d}: psnr={r.psnr:5.2f} "
                          f"swaps_seen={swaps} "
                          f"latency={r.latency_s:.2f}s", flush=True)
                i += 1
        except BaseException as e:       # a dead stream must fail the demo
            stream_errs.append(e)

    t = threading.Thread(target=stream)
    t.start()
    loop.join()
    t.join()
    engine.close()
    if stream_errs:
        raise stream_errs[0]

    s = engine.stats()
    first = [p for p, sw, _ in records if sw == 0] or [records[0][0]]
    last_epoch = max(sw for _, sw, _ in records)
    last = [p for p, sw, _ in records if sw == last_epoch]
    timeouts = sum(1 for _, _, to in records if to)
    print("== fine-tune/serve summary ==")
    print(f"served {len(records)} views, {timeouts} timeouts, "
          f"{s['field_swaps']} live swaps "
          f"(max swap {s['swap_latency_s_max'] * 1e3:.1f}ms)")
    print(f"psnr before first swap {np.mean(first):.1f} dB -> "
          f"after last swap {np.mean(last):.1f} dB")
    assert s["field_swaps"] >= 2, "expected at least two live swaps"
    assert timeouts == 0 and s["timeouts"] == 0, "futures were dropped"
    assert np.mean(last) > np.mean(first), "fine-tuning did not improve PSNR"
    print("online fine-tuning refreshed the served field with zero dropped "
          "requests (serving/finetune.py).")


if __name__ == "__main__":
    main()
