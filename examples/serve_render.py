"""Batched novel-view rendering service demo (the paper's AR/VR serving
scenario): one trained field goes resident in a serving.RenderEngine, a
stream of camera-pose requests is submitted, and the engine micro-batches
them through its single jitted render step with octant-cached
view-dependent cube ordering.

    PYTHONPATH=src python examples/serve_render.py --views 4
    PYTHONPATH=src python examples/serve_render.py --ckpt-dir /tmp/chair  # reuse
"""
import argparse

import numpy as np

from repro.configs.rtnerf import NeRFConfig
from repro.data import rays as rays_lib
from repro.serving import RenderEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scene", default="chair")
    ap.add_argument("--views", type=int, default=4)
    ap.add_argument("--res", type=int, default=56)
    ap.add_argument("--ckpt-dir", default=None,
                    help="train once and checkpoint here; repeated runs "
                         "restore instead of retraining")
    args = ap.parse_args()

    cfg = NeRFConfig(grid_res=40, occ_res=40, cube_size=4, max_cubes=768,
                     r_sigma=8, r_color=16, app_dim=12, mlp_hidden=32,
                     max_samples_per_ray=112, train_rays=1024)
    print("preparing field (train once or restore, serve many)...")
    engine = RenderEngine.from_scene(
        cfg, args.scene, ckpt_dir=args.ckpt_dir, train_steps=250, n_views=8,
        image_hw=args.res, prune_sparsity=0.9, verbose=False,
        ray_chunk=args.res * args.res, max_batch_views=args.views)

    scene = rays_lib.make_scene(args.scene)
    cams = rays_lib.make_cameras(args.views, args.res, args.res)
    futures = [engine.submit(cam, rays_lib.render_gt(scene, cam))
               for cam in cams]                     # request stream
    psnrs = []
    for i, fut in enumerate(futures):
        r = fut.result()
        psnrs.append(r.psnr)
        print(f"request {i}: psnr={r.psnr:5.2f}  latency={r.latency_s:5.2f}s  "
              f"cubes={r.stats['occ_accesses']:.0f}")
    s = engine.stats()
    print(f"served {s['views_served']} views | avg psnr {np.mean(psnrs):.2f} "
          f"| {s['fps']:.2f} FPS  p50={s['latency_p50_s']:.2f}s "
          f"p95={s['latency_p95_s']:.2f}s | ordering-cache "
          f"hits={s['ordering_cache']['hits']} | "
          f"{s['compression_ratio']:.1f}x factor compression (CPU; TPU "
          f"roofline in EXPERIMENTS.md)")


if __name__ == "__main__":
    main()
