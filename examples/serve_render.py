"""Batched novel-view rendering service demo (the paper's AR/VR serving
scenario): one trained field, a stream of camera-pose requests, rendered
through the RT-NeRF pipeline with view-dependent cube ordering per request.

    PYTHONPATH=src python examples/serve_render.py --views 4
"""
import argparse
import time

import numpy as np

from repro.configs.rtnerf import NeRFConfig
from repro.core import train as nerf_train
from repro.data import rays as rays_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scene", default="chair")
    ap.add_argument("--views", type=int, default=4)
    ap.add_argument("--res", type=int, default=56)
    args = ap.parse_args()

    cfg = NeRFConfig(grid_res=40, occ_res=40, cube_size=4, max_cubes=768,
                     r_sigma=8, r_color=16, app_dim=12, mlp_hidden=32,
                     max_samples_per_ray=112, train_rays=1024)
    print("preparing field (train once, serve many)...")
    res = nerf_train.train_nerf(cfg, args.scene, steps=250, n_views=8,
                                image_hw=args.res, log_every=10_000,
                                verbose=False)
    scene = rays_lib.make_scene(args.scene)
    cams = rays_lib.make_cameras(args.views, args.res, args.res)

    psnrs, times = [], []
    for i, cam in enumerate(cams):       # request stream
        gt = rays_lib.render_gt(scene, cam)
        t0 = time.time()
        p, stats, img = nerf_train.eval_view(res.params, cfg, res.cubes, cam,
                                             gt, pipeline="rtnerf", chunk=8)
        dt = time.time() - t0
        psnrs.append(p)
        times.append(dt)
        print(f"request {i}: psnr={p:5.2f}  {dt:5.2f}s  "
              f"tile={stats['tile']:.0f}  cubes={stats['n_cubes']:.0f}")
    print(f"served {args.views} views | avg psnr {np.mean(psnrs):.2f} | "
          f"{1.0 / np.mean(times[1:] or times):.2f} FPS steady-state (CPU; "
          f"TPU roofline in EXPERIMENTS.md)")


if __name__ == "__main__":
    main()
