"""Multi-scene serving from one process: SceneStore + scene-routed engine.

Two scenes go resident in ONE RenderEngine under a deliberately tight
device-memory budget (`max_resident_bytes` sized for ~1.5 fields), so
routing a request stream across both scenes forces LRU evictions to
encoded checkpoints and transparent revivals. A FineTuneLoop attaches to
one scene and runs a fine-tune round while the other keeps serving —
publishes go through the store, so fine-tuning and eviction can't race.

Checked as it runs (this doubles as the CI multi-scene smoke):
  * interleaved requests against both scenes all resolve, zero drops or
    timeouts, and each result matches its own scene (cross-scene PSNR
    would be garbage);
  * at least one eviction + revival happened, and a revived scene renders
    BIT-IDENTICALLY to its pre-eviction self (the spill round-trips the
    encoded streams, never decompressing);
  * the fine-tuned scene's served PSNR improves while the bystander
    scene's field is untouched.

    PYTHONPATH=src python examples/multi_scene_serve.py
    PYTHONPATH=src python examples/multi_scene_serve.py --tiny   # CI smoke
"""
import argparse

import numpy as np

from repro.configs.rtnerf import demo_config
from repro.core import train as nerf_train
from repro.data import rays as rays_lib
from repro.serving import FineTuneLoop, RenderEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenes", default="lego,chair")
    ap.add_argument("--res", type=int, default=48)
    ap.add_argument("--train-steps", type=int, default=60)
    ap.add_argument("--finetune-steps", type=int, default=60)
    ap.add_argument("--publish-every", type=int, default=20)
    ap.add_argument("--rounds", type=int, default=3,
                    help="passes over the interleaved two-scene stream")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke shape: tiny fields, 24^2 views")
    args = ap.parse_args()
    if args.tiny:
        args.res = min(args.res, 24)
        args.train_steps = min(args.train_steps, 12)
        args.finetune_steps, args.publish_every = 30, 10
    cfg = demo_config(tiny=args.tiny)
    names = args.scenes.split(",")
    assert len(names) == 2, "demo serves exactly two scenes"
    a, b = names

    print(f"== training two scenes ({a}, {b}) ==")
    fields = {}
    for name in names:
        res = nerf_train.train_nerf(cfg, name, steps=args.train_steps,
                                    n_views=6, image_hw=args.res,
                                    verbose=False)
        fields[name] = res

    # budget for ~1.5 resident fields: serving both scenes forces the
    # store to evict/revive as the stream alternates
    one = fields[a].field.factor_bytes()
    budget = int(1.5 * max(one, fields[b].field.factor_bytes()))
    engine = RenderEngine(cfg, fields[a].field, fields[a].cubes,
                          scene_name=a, max_resident_bytes=budget,
                          ray_chunk=args.res * args.res, max_batch_views=4)
    engine.register_scene(b, fields[b].field, fields[b].cubes)
    store = engine.store
    print(f"budget {budget} B, resident after both registered: "
          f"{store.resident_scenes()} (evictions={store.evictions_total})")

    cams = rays_lib.make_cameras(4, args.res, args.res)
    gts = {n: [rays_lib.render_gt(rays_lib.make_scene(n), c) for c in cams]
           for n in names}

    # reference renders per scene (forces b resident; a may get evicted)
    refs = {n: [np.asarray(engine.submit(c, scene=n).result().img)
                for c in cams] for n in names}

    print("== interleaved two-scene stream across evictions ==")
    served = 0
    for rnd in range(args.rounds):
        futs = [(n, i, engine.submit(cams[i], gts[n][i], scene=n))
                for i in range(len(cams)) for n in names]
        for n, i, fut in futs:
            r = fut.result()
            assert not r.timed_out, "request dropped across an eviction"
            assert np.array_equal(np.asarray(r.img), refs[n][i]), \
                f"scene '{n}' view {i} changed across evict/revive"
            served += 1
        s = engine.stats()
        print(f"round {rnd}: served={s['views_served']} "
              f"resident={s['resident_scenes']} "
              f"evictions={s['evictions']} revivals={s['revivals']}")

    s = engine.stats()
    assert s["evictions"] >= 1 and s["revivals"] >= 1, \
        "budget never forced an eviction — demo shape too small?"
    assert s["timeouts"] == 0

    print(f"== fine-tune round on '{a}' while '{b}' keeps serving ==")
    psnr_b_before = float(np.mean(
        [engine.submit(c, g, scene=b).result().psnr
         for c, g in zip(cams, gts[b])]))
    loop = FineTuneLoop.attach(store, a, steps=args.finetune_steps,
                               publish_every=args.publish_every,
                               n_views=6, image_hw=args.res).start()
    while loop.running():
        for c, g in zip(cams, gts[b]):
            r = engine.submit(c, g, scene=b).result()
            assert not r.timed_out
    loop.join()
    psnr_a = float(np.mean(
        [engine.submit(c, g, scene=a).result().psnr
         for c, g in zip(cams, gts[a])]))
    psnr_b_after = float(np.mean(
        [engine.submit(c, g, scene=b).result().psnr
         for c, g in zip(cams, gts[b])]))

    s = engine.stats()
    print("== multi-scene summary ==")
    print(f"served {s['views_served']} views over {s['n_scenes']} scenes, "
          f"{s['evictions']} evictions, {s['revivals']} revivals, "
          f"{s['field_swaps']} fine-tune swaps, {s['timeouts']} timeouts")
    print(f"scene '{a}' psnr after fine-tune: {psnr_a:.2f} dB; "
          f"scene '{b}' psnr {psnr_b_before:.2f} -> {psnr_b_after:.2f} dB "
          f"(bystander, unchanged field)")
    assert s["field_swaps"] >= 2, "fine-tune round published < 2 swaps"
    assert s["timeouts"] == 0, "futures were dropped"
    assert abs(psnr_b_after - psnr_b_before) < 1e-3, \
        "fine-tuning one scene disturbed another scene's field"
    print("one process served two scenes across evictions with zero "
          "dropped requests (serving/store.py).")


if __name__ == "__main__":
    main()
