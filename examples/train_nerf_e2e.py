"""End-to-end driver: train TensoRF fields on several procedural scenes for
a few hundred steps (compressed-native: factors hybrid-encoded between
optimizer steps after the first occupancy rebuild), report the encoding
decision per factor (paper H1), and evaluate both pipelines straight from
the encoded field.

    PYTHONPATH=src python examples/train_nerf_e2e.py [--scenes lego,mic]
"""
import argparse
import time

from repro.configs.rtnerf import NeRFConfig
from repro.core import train as nerf_train
from repro.data import rays as rays_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenes", default="lego,mic")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--res", type=int, default=56)
    args = ap.parse_args()

    cfg = NeRFConfig(grid_res=48, occ_res=48, cube_size=4, max_cubes=1024,
                     r_sigma=8, r_color=16, app_dim=12, mlp_hidden=32,
                     max_samples_per_ray=128, train_rays=1024)

    for scene_name in args.scenes.split(","):
        print(f"=== {scene_name} ===")
        t0 = time.time()
        res = nerf_train.train_nerf(cfg, scene_name, steps=args.steps,
                                    n_views=10, image_hw=args.res,
                                    log_every=args.steps // 3)
        print(f"  trained in {time.time() - t0:.0f}s, "
              f"cubes={res.cubes.count}")

        # H1: hybrid encoding decision per factor (the field is already
        # encoded — this is the trainer's resident representation)
        rep = res.field.sparsity_report()
        dense_b = sum(v["dense_bytes"] for v in rep.values())
        hyb_b = sum(v["bytes"] for v in rep.values())
        n_coo = sum(1 for v in rep.values() if v["format"] == "coo")
        print(f"  factors: {len(rep)} ({n_coo} coo), storage "
              f"{dense_b / 1e6:.2f}MB -> {hyb_b / 1e6:.2f}MB "
              f"({dense_b / hyb_b:.2f}x)")

        scene = rays_lib.make_scene(scene_name)
        cam = rays_lib.make_cameras(9, args.res, args.res)[4]
        gt = rays_lib.render_gt(scene, cam)
        for pl in ("uniform", "rtnerf"):
            p, stats, _ = nerf_train.eval_view(res.field, cfg, res.cubes,
                                               cam, gt, pipeline=pl,
                                               chunk=8 if pl == "rtnerf" else 1)
            print(f"  {pl:8s} psnr={p:.2f} "
                  f"occ_accesses={stats['occ_accesses']:.0f}")


if __name__ == "__main__":
    main()
