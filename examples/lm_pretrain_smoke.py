"""LM substrate driver: ~100M-param llama-style model trained for a few
hundred steps on the synthetic token stream, with checkpoint/restart and the
elastic runtime — the 'train a ~100M model for a few hundred steps' example.

    PYTHONPATH=src python examples/lm_pretrain_smoke.py --steps 300
(defaults use a smaller model so CPU finishes in minutes; pass --d-model 768
--layers 12 for the full ~100M.)
"""
import argparse
import dataclasses

from repro.configs.registry import ARCHS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_smoke")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs.base import ShapeConfig
    from repro.data.tokens import TokenStream
    from repro.launch.elastic import ElasticRunner
    from repro.launch.steps import build_train_step
    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer as tf
    from repro.models.common import split_pl
    from repro.models.sharding import make_rules
    from repro.optim import adamw, cosine_schedule

    cfg = dataclasses.replace(
        ARCHS["llama3.2-1b"], name="llama-smoke",
        n_layers=args.layers, d_model=args.d_model,
        n_heads=max(args.d_model // 64, 1),
        n_kv_heads=max(args.d_model // 128, 1),
        d_ff=args.d_model * 4, vocab=8192, head_dim=0)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    stream = TokenStream(cfg, shape)

    def build(mesh):
        rules = make_rules(mesh)
        params, _ = split_pl(tf.init_model(cfg, jax.random.PRNGKey(0)))
        n = sum(p.size for p in jax.tree.leaves(params))
        print(f"model: {n / 1e6:.1f}M params")
        opt = adamw(lr=3e-4, schedule=cosine_schedule(20, args.steps))
        state = opt.init(params)
        step = jax.jit(build_train_step(cfg, rules, opt))

        def step_fn(st, batch):
            p, s = st
            p, s, m = step(p, s, batch)
            return (p, s), m
        return step_fn, (params, state), None

    runner = ElasticRunner(build=build, ckpt_dir=args.ckpt_dir,
                           ckpt_every=50)
    state, log = runner.run(args.steps, lambda s: stream.batch(s))
    losses = [l[2] for l in log if l[0] == "step"]
    print(f"steps={len(losses)} first_loss={losses[0]:.3f} "
          f"last_loss={losses[-1]:.3f}")
    assert losses[-1] < losses[0], "loss should decrease"


if __name__ == "__main__":
    main()
